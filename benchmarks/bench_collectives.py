"""All five paper collectives (+ allreduce/allgather extensions) across
topologies and regimes — one row per (op, topology, size, variant), driven
entirely through the public :class:`repro.core.Communicator` API.

Also reports the observed trade-off table: where multilevel wins (latency /
message-count bound) and where bandwidth concentration loses (large gather/
scatter onto one slow link) — the honest version of the paper's Table.
"""
from __future__ import annotations

import sys

import numpy as np

from repro.core import OPS, Communicator
from repro.core.topology import (Topology, WAN, LAN, SMP,
                                 paper_fig8_topology, tpu_v5e_multipod)

# variant name -> Communicator tree-selection policy
VARIANTS = {
    "binomial-oblivious": "oblivious",
    "multilevel": "paper",
    "adaptive": "adaptive",
}


def many_clusters():
    site = [i // 16 for i in range(64)]
    mach = [i // 4 for i in range(64)]
    return Topology(np.stack([site, mach], 1), [WAN, LAN, SMP])


TOPOLOGIES = {
    "fig8": paper_fig8_topology(),
    "many-clusters": many_clusters(),
    "tpu-2pod": tpu_v5e_multipod(pods=2, boards=8, chips_per_board=4),
}


def run_op(comm: Communicator, op: str, nbytes: float):
    """One collective through the public API (uniform over the seven ops)."""
    if op == "barrier":
        return comm.barrier()
    if OPS[op].rootful:
        return getattr(comm, op)(nbytes, root=0)
    return getattr(comm, op)(nbytes)


def run(out=sys.stdout) -> list[dict]:
    rows = []
    print("topology,op,size_bytes,variant,seconds", file=out)
    for tname, topo in TOPOLOGIES.items():
        comms = {v: Communicator(topo, policy=p, backend="sim")
                 for v, p in VARIANTS.items()}
        for oname, spec in OPS.items():
            for nb in (1e3, 64e3):
                for vname, comm in comms.items():
                    t = run_op(comm, oname, nb).time
                    rows.append({"topology": tname, "op": oname,
                                 "size": nb, "variant": vname, "s": t})
                    print(f"{tname},{oname},{nb:.0f},{vname},{t:.6f}",
                          file=out)
                if not spec.sized:
                    break  # barrier has no size sweep
        for vname, comm in comms.items():
            # stderr: keeps the stdout stream pure CSV for naive consumers
            print(f"{tname}/{vname} plan cache: {comm.cache_info()}",
                  file=sys.stderr)
    return rows


def summarize(rows) -> list[str]:
    """Win/loss table for multilevel vs oblivious."""
    out = []
    for t in TOPOLOGIES:
        wins = losses = 0
        for op in OPS:
            for nb in (1e3, 64e3):
                sel = {r["variant"]: r["s"] for r in rows
                       if r["topology"] == t and r["op"] == op
                       and r["size"] in (nb, 1e3)}
                if not sel or "multilevel" not in sel:
                    continue
                if sel["multilevel"] <= sel["binomial-oblivious"]:
                    wins += 1
                else:
                    losses += 1
        out.append(f"{t}: multilevel wins {wins}, loses {losses} "
                   f"(losses are bandwidth-concentration cases)")
    return out


if __name__ == "__main__":
    rows = run()
    for line in summarize(rows):
        print("#", line)
