"""Static-analysis benchmark: verifier throughput and the sanitize-mode
runtime budget.  Persists ``BENCH_analysis.json``.

Three sections:

``verifier``
    Full verification (structure + members + DAG + conservation + symbolic
    semantics) of every op's tree lowering on the 512-chip pod, plus the
    sag/rsag large-message programs — wall time per program and sends/s
    throughput.  The point is that machine-checking a production-scale
    plan costs milliseconds, so re-proving the cache after every
    ``repair()`` is a non-event.
``sanitize``
    ``simulate_rounds(..., sanitize=True)`` vs plain execution over a fig8
    size sweep, median paired CPU-time ratio (same harness as bench_obs).
    The quick_check memoises per ``Lowered`` object, so steady-state
    (cached plans, the only regime that matters on a hot path) overhead is
    one WeakSet lookup; the headline asserts the 64 MiB steady-state row
    stays under the 5% budget.
``lint``
    ``lint_tree`` over ``src/repro``: file count, wall time, and finding
    count — asserted ZERO, the same contract the CI gate enforces.

``--smoke`` runs a reduced leg and checks the committed artifact's schema
instead of overwriting it (see ``bench_schema.py``); CI runs this.
"""
from __future__ import annotations

import json
import os
import statistics
import sys
import time

from repro.analysis.lint import lint_tree
from repro.analysis.verify import verify_lowered
from repro.core import Communicator
from repro.core import rounds as R
from repro.core.simulator import _SANITIZED, simulate_rounds
from repro.core.topology import paper_fig8_topology, tpu_v5e_multipod
from repro.core.trees import PAPER_POLICY, build_multilevel_tree

KIB, MIB = 1024.0, float(1 << 20)
ALL_OPS = ("bcast", "reduce", "barrier", "gather", "scatter", "allreduce",
           "allgather")
BUDGET_PCT = 5.0


def _paired_overhead(fn_a, fn_b, reps: int) -> tuple[float, float, float]:
    """Median of back-to-back CPU-time ratios (see bench_obs for why this
    is robust on noisy shared machines)."""
    ta, tb, ratios = [], [], []
    for _ in range(reps):
        t0 = time.process_time()
        fn_a()
        a = time.process_time() - t0
        t0 = time.process_time()
        fn_b()
        b = time.process_time() - t0
        ta.append(a)
        tb.append(b)
        ratios.append(b / a)
    return (statistics.median(ta), statistics.median(tb),
            statistics.median(ratios))


def verifier_section(smoke: bool) -> list[dict]:
    topo = tpu_v5e_multipod()
    members = tuple(range(topo.nprocs))
    tree = build_multilevel_tree(topo, 0, members, PAPER_POLICY)
    nb = MIB if smoke else 16 * MIB
    ops = ("bcast", "allreduce", "gather") if smoke else ALL_OPS
    progs = [(f"{op}/tree", R.lower_tree(op, tree, topo, nb, "bdp"))
             for op in ops]
    progs.append(("bcast/sag", R.lower_sag_bcast(topo, 0, members, nb,
                                                 "bdp")))
    progs.append(("allreduce/rsag",
                  R.lower_rsag_allreduce(topo, members, nb, "bdp")))
    rows = []
    for name, low in progs:
        t0 = time.perf_counter()
        findings = verify_lowered(low)
        dt = time.perf_counter() - t0
        rows.append({
            "program": name, "nprocs": topo.nprocs,
            "size_mib": nb / MIB, "n_sends": len(low.sends),
            "verify_ms": dt * 1e3,
            "sends_per_s": len(low.sends) / dt if dt > 0 else 0.0,
            "findings": len(findings),
        })
    return rows


def sanitize_section(smoke: bool) -> list[dict]:
    topo = paper_fig8_topology()
    comm = Communicator(topo, policy="auto", backend="sim")
    sizes = (MIB, 64 * MIB) if smoke else (64 * KIB, MIB, 8 * MIB,
                                           64 * MIB)
    reps = 11 if smoke else 15
    rows = []
    for nb in sizes:
        low = comm.plan("allreduce", nbytes=nb).lower(nb)
        # steady state: the program has passed the gate once already (the
        # cached-plan regime every training/serving step runs in)
        simulate_rounds(low, topo, sanitize=True)
        plain, san, ratio = _paired_overhead(
            lambda: simulate_rounds(low, topo),
            lambda: simulate_rounds(low, topo, sanitize=True),
            reps)
        # cold: first sight of the program object (once per plan build)
        t0 = time.process_time()
        _SANITIZED.discard(low)
        simulate_rounds(low, topo, sanitize=True)
        cold = time.process_time() - t0
        rows.append({
            "size_mib": nb / MIB, "n_sends": len(low.sends),
            "plain_ms": plain * 1e3, "sanitized_ms": san * 1e3,
            "overhead_pct": (ratio - 1.0) * 100.0,
            "cold_first_check_ms": cold * 1e3,
        })
    return rows


def lint_section() -> dict:
    root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src", "repro")
    nfiles = sum(1 for dp, _, fns in os.walk(root)
                 for fn in fns if fn.endswith(".py"))
    t0 = time.perf_counter()
    findings = lint_tree(root)
    dt = time.perf_counter() - t0
    return {"files": nfiles, "findings": len(findings),
            "wall_ms": dt * 1e3}


def build_doc(smoke: bool = False) -> dict:
    ver = verifier_section(smoke)
    san = sanitize_section(smoke)
    lint = lint_section()

    verify_clean = all(r["findings"] == 0 for r in ver)
    worst_ms = max(r["verify_ms"] for r in ver)
    big = [r for r in san if r["size_mib"] == 64.0]
    worst_overhead = max(r["overhead_pct"] for r in big)
    sanitize_ok = worst_overhead < BUDGET_PCT
    lint_ok = lint["findings"] == 0
    headline = {
        "verifier_programs": len(ver),
        "verifier_clean": verify_clean,
        "verifier_worst_ms": worst_ms,
        "sanitize_overhead_pct_64mib": worst_overhead,
        "budget_pct": BUDGET_PCT,
        "sanitize_passed": sanitize_ok,
        "lint_findings": lint["findings"],
        "lint_passed": lint_ok,
        "passed": verify_clean and sanitize_ok and lint_ok,
    }
    summary = [
        f"verifier (512-chip, {ver[0]['size_mib']:g} MiB): "
        f"{len(ver)} programs, 0 findings, worst {worst_ms:.1f} ms",
    ]
    for r in ver:
        summary.append(
            f"  {r['program']}: {r['n_sends']} sends, "
            f"{r['verify_ms']:.1f} ms ({r['sends_per_s']:,.0f} sends/s)")
    summary.append(
        f"sanitize steady-state overhead (fig8 allreduce): worst 64 MiB "
        f"row {worst_overhead:+.2f}% (budget {BUDGET_PCT:g}%: "
        f"{'PASS' if sanitize_ok else 'FAIL'})")
    for r in san:
        summary.append(
            f"  {r['size_mib']:g} MiB: {r['plain_ms']:.3f} -> "
            f"{r['sanitized_ms']:.3f} ms ({r['overhead_pct']:+.2f}%), "
            f"cold first check {r['cold_first_check_ms']:.2f} ms")
    summary.append(
        f"lint: {lint['files']} files in {lint['wall_ms']:.0f} ms, "
        f"{lint['findings']} findings "
        f"({'PASS' if lint_ok else 'FAIL'})")
    return {
        "generated_by": "benchmarks/bench_analysis.py",
        "verifier": ver,
        "sanitize": san,
        "lint": lint,
        "headline": headline,
        "summary": summary,
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_analysis.json")
    doc = build_doc(smoke=smoke)
    for line in doc["summary"]:
        print("#", line)
    if smoke:
        from bench_schema import check_against_committed

        drifts = check_against_committed(doc, path)
        if drifts:
            print("BENCH_analysis.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            return 1
        if not doc["headline"]["passed"]:
            print("analysis acceptance failed:", doc["headline"],
                  file=sys.stderr)
            return 1
        print("# smoke: schema matches committed BENCH_analysis.json")
        return 0
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print("# wrote BENCH_analysis.json")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    raise SystemExit(main())
