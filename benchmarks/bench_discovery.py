"""Topology discovery benchmark: recovery accuracy vs. probe noise, and the
plan-quality cost of planning on a *discovered* topology instead of the
ground truth.

Two curves, persisted to ``BENCH_discovery.json`` at the repo root:

accuracy
    For each (topology, noise level): the fraction of probe seeds whose
    discovered stratum partition is EXACTLY the ground truth's, whether the
    stratum count was right, and the worst per-level parameter error of the
    exact runs.  This quantifies where the Estefanel–Mounié style clustering
    stops being trustworthy.
regret
    Simulated bcast/allreduce wall-clock of ``policy="auto"`` plans chosen
    on the discovered topology but *charged on the true network*, relative
    to plans chosen on the truth — the end-to-end price of discovery error
    across the 1 KiB–64 MiB sweep.

``--smoke`` runs a reduced sweep and, instead of overwriting the committed
artifact, checks its schema against the fresh document (see
``bench_schema.py``) — CI runs this so benchmark refactors cannot silently
drift from the persisted JSON.
"""
from __future__ import annotations

import json
import os
import sys

import numpy as np

from repro.core import Communicator
from repro.core.discovery import fit_topology, simulated_probes
from repro.core.simulator import simulate_rounds
from repro.core.topology import paper_fig8_topology, tpu_v5e_multipod

NOISES = (0.0, 0.02, 0.05, 0.10, 0.15, 0.20, 0.30, 0.50, 0.60, 0.70, 0.80)
SEEDS = tuple(range(5))
REGRET_NOISES = (0.0, 0.05, 0.10)
REGRET_SIZES = tuple(float(1 << k) for k in range(10, 27, 2))  # 1KiB..64MiB
OPS = ("bcast", "allreduce")

# Accuracy runs at full fleet scale (512 chips); the regret sweep plans
# auto-policy collectives per size, so it uses the same reduced TPU config
# as bench_collectives to stay interactive.
ACCURACY_TOPOLOGIES = {
    "fig8": paper_fig8_topology,
    "tpu-2pod-512": tpu_v5e_multipod,
}
REGRET_TOPOLOGIES = {
    "fig8": paper_fig8_topology,
    "tpu-2pod-64": lambda: tpu_v5e_multipod(pods=2, boards=8,
                                            chips_per_board=4),
}


def _same_partition(a, b) -> bool:
    joint = len(np.unique(np.stack([np.asarray(a), np.asarray(b)], 1),
                          axis=0))
    return joint == len(np.unique(a)) == len(np.unique(b))


def _exact(truth, disc) -> bool:
    return disc.nstrata == truth.nstrata and all(
        _same_partition(truth.coords[:, l], disc.coords[:, l])
        for l in range(truth.nstrata))


def _level_err(truth, disc) -> float:
    """Worst relative error over levels × {latency, bandwidth, overhead}."""
    worst = 0.0
    for t, d in zip(truth.levels, disc.levels):
        for a, b in ((t.latency, d.latency), (t.bandwidth, d.bandwidth),
                     (t.overhead, d.overhead)):
            if a > 0:
                worst = max(worst, abs(b - a) / a)
    return worst


def accuracy(topologies, noises=NOISES, seeds=SEEDS) -> list[dict]:
    rows = []
    for tname, make in topologies.items():
        truth = make()
        for noise in noises:
            exact = strata_ok = 0
            errs = []
            for seed in seeds:
                disc = fit_topology(simulated_probes(truth, noise=noise,
                                                     seed=seed))
                strata_ok += disc.nstrata == truth.nstrata
                if _exact(truth, disc):
                    exact += 1
                    errs.append(_level_err(truth, disc))
            rows.append({
                "topology": tname, "nprocs": truth.nprocs, "noise": noise,
                "seeds": len(seeds),
                "exact_partition_rate": exact / len(seeds),
                "strata_count_rate": strata_ok / len(seeds),
                "level_param_worst_rel_err": max(errs) if errs else None,
            })
    return rows


def regret(topologies, noises=REGRET_NOISES, sizes=REGRET_SIZES,
           seed=0) -> list[dict]:
    rows = []
    for tname, make in topologies.items():
        truth = make()
        comm_true = Communicator(truth, policy="auto")
        for noise in noises:
            disc = fit_topology(simulated_probes(truth, noise=noise,
                                                 seed=seed))
            comm_disc = Communicator(disc, policy="auto")
            for op in OPS:
                for nb in sizes:
                    t_true = max(simulate_rounds(
                        comm_true.plan(op, root=0, nbytes=nb).lower(nb),
                        truth).values())
                    t_disc = max(simulate_rounds(
                        comm_disc.plan(op, root=0, nbytes=nb).lower(nb),
                        truth).values())
                    rows.append({
                        "topology": tname, "noise": noise, "op": op,
                        "size_bytes": nb, "true_s": t_true,
                        "discovered_s": t_disc,
                        "regret": t_disc / t_true - 1.0,
                    })
    return rows


def summarize(acc_rows, reg_rows) -> list[str]:
    out = []
    for tname in sorted({r["topology"] for r in acc_rows}):
        ok = [r["noise"] for r in acc_rows
              if r["topology"] == tname and r["exact_partition_rate"] == 1.0]
        out.append(f"{tname}: exact partition recovery up to "
                   f"{max(ok) * 100:.0f}% probe noise" if ok else
                   f"{tname}: no noise level with full recovery")
    for tname in sorted({r["topology"] for r in reg_rows}):
        worst = max(r["regret"] for r in reg_rows
                    if r["topology"] == tname)
        out.append(f"{tname}: worst plan regret "
                   f"{worst * 100:.2f}% across the sweep")
    return out


def build_doc(smoke: bool = False) -> dict:
    if smoke:
        acc = accuracy({"fig8": paper_fig8_topology},
                       noises=(0.0, 0.10), seeds=(0, 1))
        reg = regret({"fig8": paper_fig8_topology}, noises=(0.0, 0.10),
                     sizes=(1024.0, 65536.0, float(1 << 20)))
    else:
        acc = accuracy(ACCURACY_TOPOLOGIES)
        reg = regret(REGRET_TOPOLOGIES)
    return {
        "generated_by": "benchmarks/bench_discovery.py",
        "probe_sizes_bytes": [1024.0, float(1 << 20)],
        "accuracy": acc,
        "regret": reg,
        "summary": summarize(acc, reg),
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_discovery.json")
    doc = build_doc(smoke=smoke)
    for line in doc["summary"]:
        print("#", line)
    if smoke:
        from bench_schema import check_against_committed

        drifts = check_against_committed(doc, path)
        if drifts:
            print("BENCH_discovery.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            return 1
        print("# smoke: schema matches committed BENCH_discovery.json")
        return 0
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print("# wrote BENCH_discovery.json")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    raise SystemExit(main())
