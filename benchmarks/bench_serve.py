"""Serving benchmark: latency/throughput frontier under open-loop load,
persisted to ``BENCH_serve.json`` at the repo root.

The serving stack (``repro.serving``) runs with the token-fabricating
``SimExecutor`` — the sweep measures *scheduling and memory policy*, not
model quality — over the paper fig8 topology: per-request decode gathers on
tensor-parallel replica groups contend with a periodic fat weight broadcast
on the shared multilevel network, priced by the priority engine.

Three sections:

``frontier``
    Offered load (Poisson, open-loop) swept across rates x scheduler
    policies (fifo / priority / slo): p50/p99 TTFT, per-token latency,
    goodput, shed count.  Past saturation, fifo's queue grows without bound
    (p99 TTFT tracks the horizon) while slo sheds late requests and keeps
    the served tail inside the deadline.
``capacity``
    Paged vs dense KV at an equal block budget: dense reserves the
    worst-case ceil(s_max/block) blocks per request at admission, paged
    allocates on demand — max concurrent requests before OOM/shed is the
    paper number for paged attention.
``headline``
    Acceptance: (a) paged max concurrency strictly above dense at equal
    memory; (b) at >= 1 overload operating point slo beats fifo on p99 TTFT.

``--smoke`` runs a reduced sweep and checks the committed artifact's schema
instead of overwriting it (see ``bench_schema.py``); CI runs this.
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core import Communicator
from repro.core.engine import Engine
from repro.core.topology import paper_fig8_topology
from repro.serving import (Scheduler, SimExecutor, SLO, make_requests,
                           poisson_arrivals, bursty_arrivals,
                           default_compute_model)

# modeled serving deployment: 1B params on a TP-8 replica, fig8 network
N_PARAMS = 1e9
FLOPS_PER_S = 2e12          # per-step roofline -> ~1 ms per 1k tokens
TP = 8
D_MODEL = 4096
BLOCK = 16
S_MAX = 256
WEIGHT_BYTES = float(1 << 26)   # 64 MiB delta bcast, every BCAST_EVERY steps
BCAST_EVERY = 64
HORIZON_S = 4.0
SLO_SPEC = SLO(ttft_s=0.3, tpot_s=0.05)

RATES = (10.0, 20.0, 40.0, 80.0)
SMOKE_RATES = (10.0, 40.0)


def _replicas(n_ranks: int = 48) -> list[tuple[int, ...]]:
    return [tuple(range(g * TP, (g + 1) * TP)) for g in range(n_ranks // TP)]


def _scheduler(policy: str, mode: str, comm, *, n_blocks: int,
               max_slots: int) -> Scheduler:
    eng = Engine(comm, policy="fifo" if policy == "fifo" else "priority",
                 age_rate=WEIGHT_BYTES)
    return Scheduler(
        SimExecutor(block_size=BLOCK), n_blocks=n_blocks, block_size=BLOCK,
        max_slots=max_slots, s_max=S_MAX, policy=policy, mode=mode,
        prefill_token_budget=256,
        compute_model=default_compute_model(N_PARAMS,
                                            flops_per_s=FLOPS_PER_S),
        engine=eng, replicas=_replicas(),
        weight_bytes=WEIGHT_BYTES, gather_bytes=D_MODEL * 2.0 / TP,
        bcast_every=BCAST_EVERY)


def frontier(comm, rates, arrival="poisson") -> list[dict]:
    rows = []
    gen = poisson_arrivals if arrival == "poisson" else bursty_arrivals
    for rate in rates:
        arr = gen(rate, HORIZON_S, seed=1)
        for policy in ("fifo", "priority", "slo"):
            reqs = make_requests(arr, vocab=512, prompt_len=(16, 48),
                                 gen_len=(8, 24), slo=SLO_SPEC, seed=2)
            sch = _scheduler(policy, "paged", comm,
                             n_blocks=1 + 8 * (S_MAX // BLOCK), max_slots=8)
            w0 = time.perf_counter()
            rep = sch.run(reqs)
            wall = time.perf_counter() - w0
            s = rep.summary()
            rows.append({
                "arrival": arrival, "offered_rate_req_s": rate,
                "policy": policy, **s, "sched_wall_s": wall,
            })
    return rows


def capacity(comm, rate: float = 40.0) -> list[dict]:
    """Equal block budget, paged vs dense admission accounting."""
    n_blocks = 1 + 3 * (S_MAX // BLOCK)   # dense fits exactly 3 requests
    rows = []
    arr = poisson_arrivals(rate, HORIZON_S, seed=1)
    for mode in ("paged", "dense"):
        reqs = make_requests(arr, vocab=512, prompt_len=(16, 48),
                             gen_len=(8, 24), seed=2)
        sch = _scheduler("fifo", mode, comm, n_blocks=n_blocks, max_slots=16)
        rep = sch.run(reqs)
        s = rep.summary()
        rows.append({
            "mode": mode, "n_blocks": n_blocks, "block_size": BLOCK,
            "s_max": S_MAX, "offered_rate_req_s": rate, **s,
        })
    return rows


def summarize(front, cap) -> tuple[dict, list[str]]:
    out = []
    by_cap = {r["mode"]: r for r in cap}
    pg, dn = by_cap["paged"], by_cap["dense"]
    out.append(
        f"capacity (equal {pg['n_blocks']} blocks): paged sustains "
        f"{pg['max_concurrent']} concurrent requests vs {dn['max_concurrent']} "
        f"dense; p99 TTFT {pg['ttft_p99_s']:.3f}s vs {dn['ttft_p99_s']:.3f}s")
    slo_wins = []
    for rate in sorted({r["offered_rate_req_s"] for r in front}):
        by = {r["policy"]: r for r in front
              if r["offered_rate_req_s"] == rate}
        f9, s9 = by["fifo"]["ttft_p99_s"], by["slo"]["ttft_p99_s"]
        overload = f9 > SLO_SPEC.ttft_s
        if overload and s9 < f9:
            slo_wins.append(rate)
        out.append(
            f"rate {rate:g}/s: p99 TTFT fifo {f9:.3f}s / priority "
            f"{by['priority']['ttft_p99_s']:.3f}s / slo {s9:.3f}s "
            f"(shed {by['slo']['n_shed']}/{by['slo']['n_requests']})"
            + (" <- overload" if overload else ""))
    headline = {
        "paged_max_concurrent": pg["max_concurrent"],
        "dense_max_concurrent": dn["max_concurrent"],
        "paged_beats_dense": pg["max_concurrent"] > dn["max_concurrent"],
        "slo_win_rates": slo_wins,
        "slo_beats_fifo_under_overload": bool(slo_wins),
        "passed": (pg["max_concurrent"] > dn["max_concurrent"]
                   and bool(slo_wins)),
    }
    out.append(
        f"headline: paged {pg['max_concurrent']} > dense "
        f"{dn['max_concurrent']} concurrent at equal memory "
        f"({'PASS' if headline['paged_beats_dense'] else 'FAIL'}); slo p99 "
        f"TTFT beats fifo at overload rates {slo_wins or 'NONE'} "
        f"({'PASS' if headline['slo_beats_fifo_under_overload'] else 'FAIL'})")
    return headline, out


def build_doc(smoke: bool = False) -> dict:
    comm = Communicator(paper_fig8_topology(), backend="sim", policy="paper")
    rates = SMOKE_RATES if smoke else RATES
    front = frontier(comm, rates)
    front += frontier(comm, (rates[-1],), arrival="bursty")
    cap = capacity(comm)
    headline, summary = summarize(front, cap)
    return {
        "generated_by": "benchmarks/bench_serve.py",
        "deployment": {
            "n_params": N_PARAMS, "flops_per_s": FLOPS_PER_S, "tp": TP,
            "block_size": BLOCK, "s_max": S_MAX,
            "weight_bcast_bytes": WEIGHT_BYTES, "bcast_every": BCAST_EVERY,
            "horizon_s": HORIZON_S, "slo_ttft_s": SLO_SPEC.ttft_s,
            "slo_tpot_s": SLO_SPEC.tpot_s, "topology": "fig8",
        },
        "frontier": front,
        "capacity": cap,
        "headline": headline,
        "summary": summary,
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_serve.json")
    doc = build_doc(smoke=smoke)
    for line in doc["summary"]:
        print("#", line)
    if smoke:
        from bench_schema import check_against_committed

        drifts = check_against_committed(doc, path)
        if drifts:
            print("BENCH_serve.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            return 1
        if not doc["headline"]["passed"]:
            print("serve acceptance failed: paged>dense concurrency and "
                  "slo<fifo p99 TTFT must both hold", file=sys.stderr)
            return 1
        print("# smoke: schema matches committed BENCH_serve.json")
        return 0
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print("# wrote BENCH_serve.json")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    raise SystemExit(main())
