"""Async-engine benchmark: overlap efficiency and contention-aware
scheduling, persisted to ``BENCH_engine.json`` at the repo root.

Three sections:

``bucket_sweep``
    The bucketed, overlapped training-step model of
    :func:`repro.core.engine.overlapped_step_times` at 64 MiB of gradients,
    swept over bucket sizes, at the communication-bound threshold (backward
    compute == serial sync time — where overlap matters most).  Records
    end-to-end ``speedup`` over the serial monolithic sync and the plan
    cache counters proving bucket plans are REUSED, not rebuilt.
``policy_comparison``
    Mixed traffic — one fat 64 MiB broadcast plus a train of small
    latency-bound collectives needing the fat transfer's first slow edge —
    under the three scheduler policies.  "priority" should collapse the
    small ops' latency without measurably hurting the fat transfer; "sim"
    should never lose to either.
``headline``
    The acceptance row: best fig8 overlapped speedup at 64 MiB (>= 1.5x).

``--smoke`` runs the fig8 subset and checks the committed artifact's
schema instead of overwriting it (see ``bench_schema.py``); CI runs this.
"""
from __future__ import annotations

import json
import os
import sys
import time

from repro.core import Communicator
from repro.core.engine import Engine, overlapped_step_times
from repro.core.topology import paper_fig8_topology, tpu_v5e_multipod

GRAD_BYTES = float(1 << 26)  # 64 MiB
N_LAYERS = 16
BUCKET_MIB = (2, 4, 8, 16, 32)

# (make_topology, communicator kwargs, contended (src, dst) slow edge)
SCENARIOS = {
    # the paper testbed: full {tree x algorithm x segment} argmin
    "fig8": (paper_fig8_topology, {"policy": "auto"}, (0, 16)),
    # 512 chips: fixed paper policy + BDP segmentation (the argmin over
    # rsag lowerings at this scale is benchmarked in bench_collectives)
    "tpu-2pod-512": (tpu_v5e_multipod,
                     {"policy": "paper", "segment_bytes": "bdp"}, (0, 256)),
}


def bucket_sweep(names) -> list[dict]:
    rows = []
    for tname in names:
        make, kw, _ = SCENARIOS[tname]
        comm = Communicator(make(), backend="sim", **kw)
        layer_bytes = [GRAD_BYTES / N_LAYERS] * N_LAYERS
        t_comm = comm.allreduce(GRAD_BYTES).time
        layer_compute = [t_comm / N_LAYERS] * N_LAYERS  # balanced step
        for mib in BUCKET_MIB:
            w0 = time.perf_counter()
            res = overlapped_step_times(comm, layer_bytes, layer_compute,
                                        bucket_bytes=mib * float(1 << 20))
            wall = time.perf_counter() - w0
            st = res["engine"].comm.stats()
            rows.append({
                "topology": tname,
                "grad_mib": GRAD_BYTES / (1 << 20),
                "bucket_mib": float(mib),
                "n_buckets": res["n_buckets"],
                "compute_s": res["compute_s"],
                "comm_serial_s": res["comm_serial_s"],
                "serial_step_s": res["serial_s"],
                "overlapped_step_s": res["overlapped_s"],
                "speedup": res["speedup"],
                "overlap_efficiency": res["overlap_efficiency"],
                "plan_cache_hits": st.hits,
                "plan_cache_misses": st.misses,
                "sim_wall_s": wall,
            })
    return rows


def policy_comparison(names) -> list[dict]:
    rows = []
    for tname in names:
        make, _, edge = SCENARIOS[tname]
        topo = make()
        for policy in ("fifo", "priority", "sim"):
            # paper-policy plans: the fat broadcast is ONE monolithic slow
            # transfer, so the small ops genuinely contend with it on
            # ``edge`` (segmented/sag plans dodge the collision by design
            # — which is the point of the sweep above, not of this table)
            comm = Communicator(topo, policy="paper", backend="sim")
            eng = Engine(comm, policy=policy)
            eng.issue("bcast", GRAD_BYTES, root=edge[0])
            small = [eng.issue("bcast", 64e3, root=edge[0], members=edge)
                     for _ in range(8)]
            w0 = time.perf_counter()
            eng.wait_all()
            wall = time.perf_counter() - w0
            rows.append({
                "topology": tname,
                "policy": policy,
                "chosen": eng.stats().last_policy,
                "n_small": len(small),
                "makespan_s": eng.now,
                "mean_small_latency_s":
                    sum(h.finished for h in small) / len(small),
                "sched_wall_s": wall,
            })
    return rows


def summarize(sweep, pol) -> tuple[dict, list[str]]:
    out = []
    best = {}
    for tname in sorted({r["topology"] for r in sweep}):
        rs = [r for r in sweep if r["topology"] == tname]
        b = max(rs, key=lambda r: r["speedup"])
        best[tname] = b
        out.append(
            f"{tname}: overlapped step {b['overlapped_step_s']:.3f}s vs "
            f"serial {b['serial_step_s']:.3f}s at {b['bucket_mib']:g} MiB "
            f"buckets — {b['speedup']:.2f}x, "
            f"{b['overlap_efficiency'] * 100:.0f}% of ideal overlap")
    for tname in sorted({r["topology"] for r in pol}):
        by = {r["policy"]: r for r in pol if r["topology"] == tname}
        out.append(
            f"{tname}: small-op latency "
            f"{by['priority']['mean_small_latency_s'] * 1e3:.1f} ms "
            f"(priority) vs {by['fifo']['mean_small_latency_s'] * 1e3:.1f} "
            f"ms (fifo); sim policy picked "
            f"{by['sim']['chosen'].split(':', 1)[-1]}")
    fb = best.get("fig8")
    headline = {
        "topology": "fig8",
        "grad_mib": GRAD_BYTES / (1 << 20),
        "best_bucket_mib": fb["bucket_mib"],
        "speedup": fb["speedup"],
        "acceptance_min_speedup": 1.5,
        "passed": fb["speedup"] >= 1.5,
    }
    out.append(f"headline: fig8 64 MiB overlapped sync {fb['speedup']:.2f}x "
               f"over serial (acceptance >= 1.5x: "
               f"{'PASS' if headline['passed'] else 'FAIL'})")
    return headline, out


def build_doc(smoke: bool = False) -> dict:
    names = ("fig8",) if smoke else ("fig8", "tpu-2pod-512")
    sweep = bucket_sweep(names)
    pol = policy_comparison(names)
    headline, summary = summarize(sweep, pol)
    return {
        "generated_by": "benchmarks/bench_engine.py",
        "compute_model": "balanced: backward compute == serial sync time, "
                         "spread uniformly over layers",
        "bucket_sweep": sweep,
        "policy_comparison": pol,
        "headline": headline,
        "summary": summary,
    }


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    smoke = "--smoke" in argv
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "BENCH_engine.json")
    doc = build_doc(smoke=smoke)
    for line in doc["summary"]:
        print("#", line)
    if smoke:
        from bench_schema import check_against_committed

        drifts = check_against_committed(doc, path)
        if drifts:
            print("BENCH_engine.json schema drift:", file=sys.stderr)
            for d in drifts:
                print(" ", d, file=sys.stderr)
            return 1
        if not doc["headline"]["passed"]:
            print("fig8 overlapped speedup below the 1.5x acceptance bar",
                  file=sys.stderr)
            return 1
        print("# smoke: schema matches committed BENCH_engine.json")
        return 0
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print("# wrote BENCH_engine.json")
    return 0


if __name__ == "__main__":
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    raise SystemExit(main())
