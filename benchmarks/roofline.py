"""Roofline table builder: merges the dry-run JSON (HLO collective census,
memory analysis, compile facts) with ANALYTIC compute/memory models.

Why analytic models: XLA's ``cost_analysis()`` counts every while-loop body
ONCE, so scan-over-layers (and the chunked-attention scans) under-count
FLOPs/bytes by orders of magnitude (observed: 2000x on tinyllama).  We keep
the raw numbers for reference but derive the roofline terms from structural
models with known trip counts.  The collective term comes from the HLO
census (reliable: collectives are never inside scans in our programs — the
gradient sync runs once per step, TP collectives are unrolled per run).

Conventions (documented in EXPERIMENTS.md §Roofline):
  * train FLOPs factor: forward 1x + backward 2x + remat re-forward 1x = 4x
    for layer compute; 3x for the (non-rematted) CE head.
  * our attention computes the FULL masked S x S score (no causal block
    skipping) -> attention FLOPs count S, not S/2; the MODEL_FLOPS ratio
    surfaces exactly this waste.
  * bytes: weights read thrice (fwd/remat/bwd) + grad write + ZeRO-1 opt
    traffic; activations ~14 x-sized r/w per layer + flash K/V re-reads;
    decode: the KV cache read dominates.
"""
from __future__ import annotations

import json
import os
import sys

from repro.configs import get_config, list_archs, SHAPES
from repro.configs.shapes import applicable
from repro.core.costmodel import TPU_V5E, roofline_terms

RESULTS = os.path.join(os.path.dirname(__file__), "results", "dryrun.json")


# ---------------------------------------------------------------------- #
# Analytic FLOPs
# ---------------------------------------------------------------------- #

def _mlp_flops_per_tok(cfg):
    if cfg.moe is not None:
        m = cfg.moe
        routed = 2 * 3 * cfg.d_model * m.d_ff_expert * m.top_k
        shared = 2 * 3 * cfg.d_model * cfg.d_ff if m.shared_expert else 0
        router = 2 * cfg.d_model * m.n_experts
        return routed + shared + router
    mult = 3 if cfg.activation in ("swiglu", "geglu") else 2
    return 2 * mult * cfg.d_model * cfg.d_ff


def attn_kv_eff(S, causal, window, block_skip, chunk=512):
    """Average kv positions COMPUTED per query under the flash blocking.

    block_skip=False: the pre-skip implementation computes every (i,j) block
    (full S).  block_skip=True: exact count of on-band blocks (lax.cond skip
    in models.layers), averaged over q blocks.

    Public: benchmarks/bench_kernels.py uses this for the Pallas flash
    kernels' analytic FLOPs (the kernels skip off-band blocks with pl.when,
    the same blocking this function counts)."""
    if not block_skip:
        return min(S, window + chunk) if (window and not causal) else S
    cq = ck = min(chunk, S)
    nq, nk = S // cq, S // ck
    total = 0
    for i in range(nq):
        for j in range(nk):
            need = True
            if causal:
                need &= j * ck <= i * cq + cq - 1
            if window is not None:
                need &= (i * cq) - (j * ck + ck - 1) < window
            total += ck if need else 0
    return total / nq


def _layer_flops_per_tok(cfg, kind, kv_len, block_skip=False, decode=False):
    D = cfg.d_model
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else None
        if decode:  # one query against the whole (windowed) cache
            eff = min(kv_len, window) if window else kv_len
        else:
            eff = attn_kv_eff(kv_len, True, window, block_skip)
        proj = 2 * (D * cfg.q_dim + 2 * D * cfg.kv_dim + cfg.q_dim * D)
        attn = 4 * cfg.n_heads * cfg.head_dim * eff
        return proj + attn + _mlp_flops_per_tok(cfg)
    if kind == "rglru":
        R = cfg.d_rnn or D
        proj = 2 * (2 * D * R + 2 * R * R + R * D)
        return proj + 30 * R + _mlp_flops_per_tok(cfg)
    if kind == "rwkv6":
        hd = cfg.rwkv_head_dim
        H = D // hd
        tm = 2 * 6 * D * D + 6 * H * hd * hd      # projections + wkv state
        cm = 2 * (2 * D * cfg.d_ff + D * D)       # channel mix
        return tm + cm
    raise ValueError(kind)


def flops_estimate(cfg, shape, block_skip: bool = False) -> float:
    """Global FLOPs for one step of (cfg x shape)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        tokens, kv_len, layer_f, head_f = B, S, 1.0, 1.0
    elif shape.kind == "prefill":
        tokens, kv_len, layer_f, head_f = B * S, S, 1.0, 1.0
    else:
        tokens, kv_len, layer_f, head_f = B * S, S, 4.0, 3.0
    dec = shape.kind == "decode"
    per_tok = sum(_layer_flops_per_tok(cfg, k, kv_len, block_skip, dec)
                  for k in cfg.pattern)
    if cfg.enc_dec:
        per_tok += cfg.enc_dec.n_enc_layers * _layer_flops_per_tok(
            cfg, "attn", kv_len, block_skip, dec)
        per_tok += cfg.n_layers * 2 * (cfg.d_model * cfg.q_dim
                                       + cfg.q_dim * cfg.d_model)  # cross
    head = 2 * cfg.d_model * cfg.vocab
    if shape.kind == "prefill":
        head_tokens = B  # prefill emits last-token logits only
    else:
        head_tokens = tokens
    return layer_f * per_tok * tokens + head_f * head * head_tokens


def model_flops(cfg, shape) -> float:
    """The 6*N*D (train) / 2*N*D (inference) yardstick over ACTIVE params,
    excluding the input embedding table (a lookup, not a matmul) but keeping
    the tied LM head via the +D*V term only where logits are computed."""
    B, S = shape.global_batch, shape.seq_len
    tokens = B if shape.kind == "decode" else B * S
    mult = 6 if shape.kind == "train" else 2
    n = cfg.active_param_count() - cfg.vocab * cfg.d_model
    head_tokens = B if shape.kind == "prefill" else tokens
    hm = 3 if shape.kind == "train" else 1
    return mult * n * tokens + hm * 2 * cfg.d_model * cfg.vocab * head_tokens


# ---------------------------------------------------------------------- #
# Analytic bytes (per chip)
# ---------------------------------------------------------------------- #

def bytes_estimate_per_chip(cfg, shape, mesh_shape) -> float:
    model = mesh_shape.get("model", 1)
    data = mesh_shape.get("data", 1)
    pods = mesh_shape.get("pod", 1)
    chips = model * data * pods
    B, S = shape.global_batch, shape.seq_len
    P = cfg.param_count()
    Wc = P * 2 / model                      # bf16 weights per chip
    if shape.kind == "train":
        toks_local = B * S / (data * pods)
        w = 3 * Wc + Wc                     # fwd+remat+bwd reads, grad write
        w += 2 * P * 12 / (model * data)    # ZeRO-1 m/v/master r+w (f32)
        act = 14 * toks_local * cfg.d_model * 2 * cfg.n_layers
        # flash K/V re-reads: every q chunk (cq=512) streams all K,V
        kv_rereads = sum(
            (min(S, cfg.window) if k == "local" else S) / 512
            * 2 * cfg.kv_dim * 2
            for k in cfg.pattern if k in ("attn", "local"))
        act += toks_local * kv_rereads * 3  # fwd + bwd(dq) + bwd(dkv) passes
        return w + act
    if shape.kind == "prefill":
        toks_local = B * S / (data * pods)
        act = 8 * toks_local * cfg.d_model * 2 * cfg.n_layers
        kv_rereads = sum(
            (min(S, cfg.window) if k == "local" else S) / 512
            * 2 * cfg.kv_dim * 2
            for k in cfg.pattern if k in ("attn", "local"))
        return Wc + act + toks_local * kv_rereads
    # decode: weights + full cache read once per token
    cache = 0.0
    for k in cfg.pattern:
        if k == "attn":
            cache += B * S * 2 * cfg.kv_dim * 2
        elif k == "local":
            cache += B * min(S, cfg.window) * 2 * cfg.kv_dim * 2
        elif k == "rwkv6":
            hd = cfg.rwkv_head_dim
            cache += B * (cfg.d_model // hd) * hd * hd * 4
        elif k == "rglru":
            cache += B * (cfg.d_rnn or cfg.d_model) * 4
    return Wc + cache / chips


# ---------------------------------------------------------------------- #
# Table builder
# ---------------------------------------------------------------------- #

def build_table(mesh: str = "16x16", comm: str = "multilevel",
                tag: str | None = None, block_skip: bool = True) -> list[dict]:
    with open(RESULTS) as f:
        res = json.load(f)
    chips = 512 if mesh == "2x16x16" else 256
    mesh_shape = ({"pod": 2, "data": 16, "model": 16} if mesh == "2x16x16"
                  else {"data": 16, "model": 16})
    rows = []
    for arch in list_archs()[:10]:
        for sname, shape in SHAPES.items():
            key = f"{arch}|{sname}|{mesh}|{comm}" + (f"|{tag}" if tag else "")
            rec = res.get(key)
            # prefer the optimized (hillclimbed) record where one exists
            for t in ("ep", "sp"):
                opt = res.get(f"{arch}|{sname}|{mesh}|{comm}|{t}")
                if opt and "error" not in opt:
                    rec = opt
            cfg = get_config(arch)
            ok, why = applicable(cfg, shape)
            if not ok:
                rows.append({"arch": arch, "shape": sname, "skipped": why})
                continue
            if rec is None or "error" in rec:
                rows.append({"arch": arch, "shape": sname,
                             "error": (rec or {}).get("error", "missing")})
                continue
            fl = flops_estimate(cfg, shape, block_skip=block_skip)
            mb = bytes_estimate_per_chip(cfg, shape, mesh_shape)
            terms = roofline_terms(
                hlo_flops=fl, hlo_bytes=mb * chips,
                ici_bytes=rec["ici_mb_per_chip"] * 1e6,
                dcn_bytes=rec["dcn_mb_per_chip"] * 1e6,
                chips=chips, hw=TPU_V5E)
            mf = model_flops(cfg, shape)
            rows.append({
                "arch": arch, "shape": sname, "mesh": mesh,
                "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
                "collective_s": terms["collective_s"], "bound": terms["bound"],
                "step_s": terms["step_s"],
                "model_flops": mf, "est_flops": fl,
                "useful_frac": mf / fl,
                "roofline_frac": (mf / (chips * TPU_V5E.peak_flops))
                                 / terms["step_s"],
                "ici_mb": rec["ici_mb_per_chip"],
                "dcn_mb": rec["dcn_mb_per_chip"],
                "compile_s": rec["compile_s"],
                "raw_hlo_gflops": rec["hlo_gflops"],
                "counts": rec.get("collective_counts", {}),
            })
    return rows


def _emit(rows, out) -> None:
    print("arch,shape,bound,compute_s,memory_s,collective_s,step_s,"
          "roofline_frac,useful_frac,ici_gb,dcn_mb", file=out)
    for r in rows:
        if "skipped" in r or "error" in r:
            print(f"{r['arch']},{r['shape']},"
                  f"{r.get('skipped') or r.get('error')}", file=out)
            continue
        print(f"{r['arch']},{r['shape']},{r['bound']},"
              f"{r['compute_s']:.5f},{r['memory_s']:.5f},"
              f"{r['collective_s']:.5f},{r['step_s']:.5f},"
              f"{r['roofline_frac']:.3f},{r['useful_frac']:.3f},"
              f"{r['ici_mb']/1e3:.2f},{r['dcn_mb']:.1f}", file=out)


def main(out=sys.stdout, block_skip: bool = True) -> None:
    for mesh in ("16x16", "2x16x16"):
        try:
            rows = build_table(mesh, block_skip=block_skip)
        except FileNotFoundError:
            print(f"# no dryrun results for {mesh}", file=out)
            continue
        print(f"# mesh {mesh}", file=out)
        _emit(rows, out)
        csv = os.path.join(os.path.dirname(RESULTS),
                           f"roofline_{mesh.replace('x', '_')}.csv")
        with open(csv, "w") as f:
            _emit(rows, f)


if __name__ == "__main__":
    main()
